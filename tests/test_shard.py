"""Sharded hierarchical scheduling: coordinator unit behaviour, indexed
MinHardSet vs a naive reference on seeded random streams, and K-shard
vs single-scheduler pruning equivalence (monotone durations)."""
import pickle
import random

import pytest

from repro.core.hardness import Hardness, MinHardSet
from repro.core.server import ServerConfig
from repro.core.shard import (ShardCoordinator, merge_cost_summaries,
                              merge_results, partition_tasks)
from repro.core.sim import ShardedSimCluster, SimCluster, SimParams, SimTask


# ---------------------------------------------------------------------------
# naive reference for the indexed MinHardSet
# ---------------------------------------------------------------------------
class NaiveMinHardSet:
    """O(frontier) reference semantics for MinHardSet (pre-index)."""

    def __init__(self):
        self.items = []

    def disqualifies(self, h):
        return any(h.geq(m) for m in self.items)

    def add(self, h):
        if self.items and self.disqualifies(h):
            return False
        self.items = [m for m in self.items if not m.geq(h)]
        self.items.append(h)
        return True

    def snapshot(self):
        return [m.values for m in self.items]


def _random_stream(rng, dims, n, lo=0, hi=6):
    return [Hardness(tuple(rng.randint(lo, hi) for _ in range(dims)))
            for _ in range(n)]


@pytest.mark.parametrize("seed,dims", [(0, 1), (1, 2), (2, 2), (3, 3),
                                       (4, 4), (5, 2)])
def test_indexed_minhardset_equals_naive(seed, dims):
    rng = random.Random(seed)
    indexed, naive = MinHardSet(), NaiveMinHardSet()
    for h in _random_stream(rng, dims, 400):
        # interleave queries and mutations; answers must agree stepwise
        probe = Hardness(tuple(rng.randint(0, 6) for _ in range(dims)))
        assert indexed.disqualifies(probe) == naive.disqualifies(probe)
        assert indexed.add(h) == naive.add(h), h.values
        assert indexed.snapshot() == naive.snapshot()
        assert len(indexed) == len(naive.items)


def test_indexed_minhardset_snapshot_roundtrip():
    rng = random.Random(7)
    ms = MinHardSet()
    for h in _random_stream(rng, 3, 200):
        ms.add(h)
    snap = ms.snapshot()
    restored = MinHardSet()
    restored.restore(snap)
    assert restored.snapshot() == snap          # byte-identical order
    # restored index answers like the original
    for _ in range(100):
        probe = Hardness(tuple(rng.randint(0, 6) for _ in range(3)))
        assert restored.disqualifies(probe) == ms.disqualifies(probe)
        assert (pickle.dumps(restored.snapshot())
                == pickle.dumps(ms.snapshot()))


# ---------------------------------------------------------------------------
# partition / coordinator units
# ---------------------------------------------------------------------------
def _grid(na, nb, base=0.2, deadline=None):
    return [SimTask((a, b), ("a", "b"), (a, b), base * (a + b + 1),
                    deadline, (a * b,))
            for a in range(na) for b in range(nb)]


def test_partition_tasks_contiguous_and_complete():
    tasks = _grid(5, 4)
    for k in (1, 2, 3, 7, 20, 25):
        parts = partition_tasks(tasks, k)
        assert len(parts) == k
        flat = [i for p in parts for i in p]
        assert sorted(flat) == list(range(len(tasks)))
        # contiguous in the hardness-sorted order: every index of shard k
        # sorts at or before every index of shard k+1
        keys = [tuple(tasks[i].hardness().values) for i in flat]
        assert keys == sorted(keys)
    with pytest.raises(ValueError):
        partition_tasks(tasks, 0)


def test_coordinator_gossips_once_and_queues_for_absent_shards():
    coord = ShardCoordinator(3)
    assert coord.observe(0, [(2, 2)]) == [(2, 2)]
    assert coord.observe(1, [(2, 2)]) == []     # global seen-set: once
    assert coord.take_pending(1) == [(2, 2)]
    assert coord.take_pending(1) == []          # drained
    # shard 2 was never pumped: its queue persists across a snapshot
    snap = coord.snapshot()
    restored = ShardCoordinator.restore(snap)
    assert restored.take_pending(2) == [(2, 2)]
    assert restored.observe(2, [(2, 2)]) == []
    assert restored.snapshot()["n_shards"] == 3


def test_merge_results_rejects_incomplete_tables():
    tasks = _grid(2, 2)
    cl = ShardedSimCluster(tasks, ServerConfig(max_clients=1,
                                               use_backup=False),
                           SimParams(), n_shards=2, _internal=True)
    cl.run(until=600)
    with pytest.raises(ValueError, match="rows"):
        merge_results([cl.acting_primaries()[0].final_results,
                       cl.acting_primaries()[1].final_results],
                      [cl.shard_indices[0], cl.shard_indices[1] + [99]])


def test_merge_cost_summaries():
    a = {"total": 1.5, "instance_seconds": 3.0,
         "by_kind": {"client": 1.0, "server": 0.5}, "instances": 2}
    b = {"total": 2.5, "instance_seconds": 5.0,
         "by_kind": {"client": 2.5}, "instances": 3}
    merged = merge_cost_summaries([a, None, b])
    assert merged == {"total": 4.0, "instance_seconds": 8.0,
                      "by_kind": {"client": 3.5, "server": 0.5},
                      "instances": 5}
    assert merge_cost_summaries([None, None]) is None


# ---------------------------------------------------------------------------
# K-shard vs single-scheduler equivalence (monotone durations)
# ---------------------------------------------------------------------------
def _status_sets(table):
    solved = {p for p, r, s in table.rows if s == "done"}
    gone = {p for p, r, s in table.rows if s in ("pruned", "timed_out")}
    return solved, gone


@pytest.mark.parametrize("n_shards", [2, 3, 5])
def test_sharded_pruning_matches_single(n_shards):
    deadline = 1.6
    single = SimCluster(_grid(7, 7, base=0.25, deadline=deadline),
                        ServerConfig(max_clients=4, use_backup=False),
                        SimParams(), _internal=True)
    t1 = single.run(until=4000).final_results
    sharded = ShardedSimCluster(
        _grid(7, 7, base=0.25, deadline=deadline),
        ServerConfig(max_clients=2, use_backup=False),
        SimParams(), n_shards=n_shards, _internal=True)
    sharded.run(until=4000)
    tk = sharded.merged_results()
    s1, g1 = _status_sets(t1)
    sk, gk = _status_sets(tk)
    assert sk == s1
    assert gk == g1
    # every task reaches exactly one terminal status, exactly once
    params = [p for p, _, _ in tk.rows]
    assert len(params) == len(set(params)) == 49
    assert sk | gk == set(params)
    # cross-shard gossip actually fired (the timed-out corner lives in
    # the hardest shard; others only learn of it through the coordinator)
    assert sharded.coordinator.seen, "no hardness was ever gossiped"


def test_sharded_with_backups_still_matches():
    deadline = 1.2
    single = SimCluster(_grid(5, 5, base=0.3, deadline=deadline),
                        ServerConfig(max_clients=3, use_backup=False),
                        SimParams(), _internal=True)
    t1 = single.run(until=4000).final_results
    sharded = ShardedSimCluster(
        _grid(5, 5, base=0.3, deadline=deadline),
        ServerConfig(max_clients=2, use_backup=True),
        SimParams(), n_shards=2, _internal=True)
    sharded.run(until=4000)
    tk = sharded.merged_results()
    assert _status_sets(tk) == _status_sets(t1)


def test_sharded_rejects_min_group_size():
    with pytest.raises(ValueError, match="min_group_size"):
        ShardedSimCluster(_grid(2, 2),
                          ServerConfig(min_group_size=2, use_backup=False),
                          SimParams(), n_shards=2, _internal=True)
