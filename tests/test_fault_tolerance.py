"""Fault-tolerance protocol tests (paper §Fault tolerance): client failure,
backup-server failure, primary failure with takeover, dangling cleanup,
exactly-once results under the two-copy delivery protocol."""
import pytest

from repro.core.server import ServerConfig
from repro.core.sim import SimCluster, SimParams, SimTask


def mk_tasks(n, dur=1.0):
    return [SimTask((i, 0), ("n", "id"), (i,), dur, None, (i,))
            for i in range(1, n + 1)]


def kill_first(prefix):
    def fn(c):
        for name in c.engine.nodes:
            if name.startswith(prefix) and c.engine.alive.get(name):
                c.engine.kill(name)
                return
    return fn


def solved_set(srv):
    return sorted(p[0] for p, r, s in srv.final_results.rows
                  if r is not None)


def test_client_failure_reassigns_tasks():
    cl = SimCluster(mk_tasks(20),
                    ServerConfig(max_clients=2, use_backup=False,
                                 health_update_limit=3.0))
    cl.at(6.0, kill_first("client"))
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, 21))


def test_primary_failure_backup_takes_over():
    # workload long enough (~20s) that the kill at t=8 lands mid-run
    cl = SimCluster(mk_tasks(40, dur=2.0),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0))
    cl.at(8.0, lambda c: c.kill_primary())
    srv = cl.run(until=900)
    assert srv.role == "primary" and srv.name == "primary*"
    assert solved_set(srv) == list(range(1, 41))
    # exactly-once: every result appears exactly once
    assert len(srv.results) == 40


def test_backup_failure_is_replaced():
    cl = SimCluster(mk_tasks(60, dur=2.0),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0))
    cl.at(4.0, kill_first("backup"))
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, 61))
    # a replacement backup was handshaken at some point
    assert srv.backup_name is not None and srv.backup_name != "backup-0"


def test_double_failure_client_then_primary():
    cl = SimCluster(mk_tasks(30, dur=1.2),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0))
    cl.at(6.0, kill_first("client"))
    cl.at(14.0, lambda c: c.kill_primary())
    srv = cl.run(until=1200)
    assert solved_set(srv) == list(range(1, 31))


def test_takeover_cleans_dangling_instances():
    """Primary dies right after creating a client that never handshook;
    the new primary must delete the unknown instance (paper §c end)."""
    cl = SimCluster(mk_tasks(12, dur=1.0),
                    ServerConfig(max_clients=3, use_backup=True,
                                 health_update_limit=3.0))

    def ghost_then_kill(c):
        # instance exists on the engine but has no client object anywhere
        c.engine._instances["client-ghost"] = c.clock.now()
        c.kill_primary()

    cl.at(8.0, ghost_then_kill)
    srv = cl.run(until=900)
    assert "client-ghost" not in cl.engine.list_instances()
    assert solved_set(srv) == list(range(1, 13))


def test_worker_crash_requeues_task():
    class CrashOnce(SimTask):
        crashed = {}

        def run(self):
            key = self.parameters()
            if not CrashOnce.crashed.get(key):
                CrashOnce.crashed[key] = True
                raise RuntimeError("boom")
            return self._result

    CrashOnce.crashed = {}
    tasks = [CrashOnce((i, 0), ("n", "id"), (i,), 0.5, None, (i,))
             for i in range(1, 6)]
    cl = SimCluster(tasks, ServerConfig(max_clients=2, use_backup=False))
    srv = cl.run(until=900)
    assert solved_set(srv) == list(range(1, 6))
