"""The pure SchedulerCore + policy layer: transport/engine purity,
snapshot->restore->replay determinism, budget/scaling/assignment policies,
and end-to-end cost accounting."""
import ast
import inspect
import pickle
import random

import pytest

from repro.core import policy as policy_mod
from repro.core import scheduler as scheduler_mod
from repro.core.hardness import Hardness
from repro.core.messages import Message, MsgType
from repro.core.policy import CostMeter
from repro.core.results import ResultsTable
from repro.core.scheduler import (ASSIGNED, DONE, CreateInstance,
                                  SchedulerCore, ServerConfig,
                                  TerminateInstance, Tick)
from repro.core.server import ServerConfig as ServerConfigReexport
from repro.core.sim import SimCluster, SimParams, SimTask


def mk_tasks(n, dur=1.0, deadline=None):
    return [SimTask((i, 0), ("n", "id"), (i,), dur, deadline, (i,))
            for i in range(1, n + 1)]


# ---------------------------------------------------------------------------
# layering: the core and the policies never touch transports or engines
# ---------------------------------------------------------------------------
def test_core_and_policy_have_no_transport_or_engine_imports():
    for mod in (scheduler_mod, policy_mod):
        tree = ast.parse(inspect.getsource(mod))
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                names = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                names = [node.module or ""]
            else:
                continue
            for name in names:
                assert "transport" not in name and "engine" not in name, \
                    f"{mod.__name__} imports {name}"


def test_server_config_reexported():
    assert ServerConfigReexport is ServerConfig


# ---------------------------------------------------------------------------
# snapshot -> restore -> replay is byte-identical to uninterrupted execution
# ---------------------------------------------------------------------------
def _random_events(seed: int, cfg: ServerConfig, n_tasks: int = 12):
    """A deterministic random protocol-faithful transcript as
    (method, args) pairs.  Generated adaptively against a scratch core
    with the same config (clients only report on tasks they own), so
    replaying it against a fresh core reproduces the same run."""
    rng = random.Random(seed)
    scratch = SchedulerCore(mk_tasks(n_tasks), cfg)
    script = []
    now = 0.0
    joined = []
    msg_seq = 0

    def emit(method, *args):
        script.append((method, args))
        getattr(scratch, method)(*args)

    def msg(mtype, sender, body=None):
        nonlocal msg_seq
        m = Message(mtype, sender, body)
        m.seq = msg_seq       # deterministic, independent of global counter
        msg_seq += 1
        return m

    for _ in range(60):
        now += rng.uniform(0.01, 0.8)
        owned = sorted((c, tid) for c, ci in scratch.clients.items()
                       for tid in ci.assigned)
        roll = rng.random()
        if roll < 0.15 or not joined:
            cname = f"c{len(joined)}"
            joined.append(cname)
            emit("client_joined", cname, now)
        elif roll < 0.45 or not owned:
            cname = rng.choice(joined)
            emit("on_message", msg(MsgType.REQUEST_TASKS, cname,
                                   {"n": rng.randint(1, 3)}), now)
        elif roll < 0.65:
            cname, tid = rng.choice(owned)
            emit("on_message", msg(MsgType.RESULT, cname,
                                   {"tid": tid, "result": (tid,)}), now)
        elif roll < 0.75:
            cname, tid = rng.choice(owned)
            emit("on_message",
                 msg(MsgType.REPORT_HARD_TASK, cname,
                     {"tid": tid,
                      "hardness": scratch.tasks[tid].hardness().values}),
                 now)
        elif roll < 0.85:
            cname, tid = rng.choice(owned)
            emit("on_message", msg(MsgType.EXCEPTION, cname,
                                   {"tid": tid, "error": "boom"}), now)
        else:
            emit("on_tick", Tick(now, pending_instances=rng.randint(0, 2),
                                 can_create=rng.random() < 0.7))
    return script


def _drive(core, script):
    out = []
    for method, args in script:
        res = getattr(core, method)(*args)
        if isinstance(res, list):
            out.extend(res)
    return out


def _norm_effects(effs):
    """Task objects lack __eq__; compare grants by tid."""
    out = []
    for e in effs:
        from repro.core.scheduler import Send
        if isinstance(e, Send) and isinstance(e.body, dict) \
                and "tasks" in e.body:
            out.append((e.client, e.mtype, e.srv_seq,
                        [tid for tid, _ in e.body["tasks"]],
                        e.body.get("requested")))
        else:
            out.append(e)
    return out


def _canonical(snapshot) -> bytes:
    """Canonical byte serialization of a snapshot (tasks/config flattened
    to their field dicts; normalizes object-identity artifacts that pickle
    memoization would otherwise surface)."""
    import json
    return json.dumps(snapshot, sort_keys=True,
                      default=lambda o: o.__dict__).encode()


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("scale", ["fixed", "demand"])
def test_snapshot_restore_replay_identical(seed, scale):
    cfg = ServerConfig(max_clients=3, scale_policy=scale, workers_hint=2)
    script = _random_events(seed, cfg)
    cut = random.Random(seed ^ 0xBEEF).randrange(1, len(script))

    a = SchedulerCore(mk_tasks(12), cfg)
    effects_a = _drive(a, script)

    b = SchedulerCore(mk_tasks(12), cfg)
    effects_head = _drive(b, script[:cut])
    blob = pickle.dumps(b.snapshot())          # the wire format
    b2 = SchedulerCore.restore(pickle.loads(blob))
    effects_tail = _drive(b2, script[cut:])

    assert _canonical(a.snapshot()) == _canonical(b2.snapshot())
    # the effect stream after the cut matches the uninterrupted run's tail
    assert _norm_effects(effects_tail) == \
        _norm_effects(effects_a[len(effects_head):])


@pytest.mark.parametrize("seed", range(4))
def test_assigned_tasks_always_owned(seed):
    """Global invariant under random transcripts: every ASSIGNED task is
    held by exactly one client (idle downscale never strands work)."""
    cfg = ServerConfig(max_clients=3, scale_policy="demand", workers_hint=2,
                       idle_timeout_s=1.0)
    core = SchedulerCore(mk_tasks(12), cfg)
    for method, args in _random_events(seed, cfg):
        getattr(core, method)(*args)
        owners = {}
        for cname, ci in core.clients.items():
            for tid in ci.assigned:
                assert tid not in owners, (tid, cname, owners[tid])
                owners[tid] = cname
        for tid, s in enumerate(core.status):
            if s == ASSIGNED:
                assert tid in owners, f"ASSIGNED task {tid} stranded"


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
def _join_and_request(core, cname, n, now=0.0):
    core.client_joined(cname, now)
    return core.on_message(
        Message(MsgType.REQUEST_TASKS, cname, {"n": n}), now)


def test_budget_policy_halts_scaling():
    cfg = ServerConfig(max_clients=10, scale_policy="fixed",
                       budget_cap=100.0, budget_reserve_s=10.0)
    core = SchedulerCore(mk_tasks(20), cfg)
    effs = core.on_tick(Tick(0.0, accrued_cost=0.0, burn_rate=1.0,
                             client_rate=1.0))
    assert any(isinstance(e, CreateInstance) for e in effs)
    # projected spend 95 + 10 * (3 + 1) = 135 > 100: creation denied
    effs = core.on_tick(Tick(1.0, accrued_cost=95.0, burn_rate=3.0,
                             client_rate=1.0))
    assert not any(isinstance(e, CreateInstance) for e in effs)
    assert any(e["body"].get("event") == "budget_cap"
               for e in core.events.for_client("server"))
    # spending back under projection resumes scaling (cap not yet reached)
    effs = core.on_tick(Tick(2.0, accrued_cost=50.0, burn_rate=1.0,
                             client_rate=1.0))
    assert any(isinstance(e, CreateInstance) for e in effs)


def test_idle_downscale_never_strands_assigned():
    cfg = ServerConfig(max_clients=4, scale_policy="demand",
                       workers_hint=4, idle_timeout_s=5.0)
    core = SchedulerCore(mk_tasks(4), cfg)
    _join_and_request(core, "worker", 4, now=0.0)    # takes all 4 tasks
    core.client_joined("idler", 0.0)
    assert len(core.clients["worker"].assigned) == 4
    # nothing grantable + idler workless beyond the cutoff -> terminated;
    # the loaded client is untouched
    effs = core.on_tick(Tick(10.0))
    terms = [e for e in effs if isinstance(e, TerminateInstance)]
    assert [t.name for t in terms] == ["idler"]
    assert terms[0].reason == "idle"
    assert "worker" in core.clients
    assert all(s == ASSIGNED for s in core.status)
    # the worker finishes: everything completes, nothing was stranded
    for tid in range(4):
        core.on_message(Message(MsgType.RESULT, "worker",
                                {"tid": tid, "result": (tid,)}), 11.0)
    core.on_tick(Tick(12.0))
    assert core.done and all(s == DONE for s in core.status)


def test_demand_policy_stops_creating_at_capacity():
    cfg = ServerConfig(max_clients=10, scale_policy="demand", workers_hint=4)
    core = SchedulerCore(mk_tasks(6), cfg)
    # 6 grantable tasks, one booting client committed at 4 workers:
    # 6 > 4 -> one more instance wanted
    effs = core.on_tick(Tick(0.0, pending_instances=1, pending_clients=1))
    assert any(isinstance(e, CreateInstance) for e in effs)
    # two booting clients commit 8 >= 6 -> no further creation
    effs = core.on_tick(Tick(0.5, pending_instances=2, pending_clients=2))
    assert not any(isinstance(e, CreateInstance) for e in effs)


def test_demand_policy_ignores_pending_backup_capacity():
    """A booting backup server is not worker capacity and must not
    suppress client creation."""
    cfg = ServerConfig(max_clients=10, scale_policy="demand", workers_hint=4)
    core = SchedulerCore(mk_tasks(4), cfg)
    effs = core.on_tick(Tick(0.0, pending_instances=1, pending_clients=0))
    assert any(isinstance(e, CreateInstance) for e in effs)


def test_backfill_policy_grants_do_not_cross_batch_boundary():
    cfg = ServerConfig(max_clients=4, assign_policy="backfill",
                       assign_batch=4)
    core = SchedulerCore(mk_tasks(12), cfg)
    core.client_joined("a", 0.0)
    core.client_joined("b", 0.0)
    # a asks for 2 of the first batch; b's request of 4 is clipped to the
    # batch remainder (2), then its next request gets the whole next batch
    [grant_a] = core.on_message(
        Message(MsgType.REQUEST_TASKS, "a", {"n": 2}), 0.0)
    assert [tid for tid, _ in grant_a.body["tasks"]] == [0, 1]
    [grant_b] = core.on_message(
        Message(MsgType.REQUEST_TASKS, "b", {"n": 4}), 0.0)
    assert [tid for tid, _ in grant_b.body["tasks"]] == [2, 3]
    assert grant_b.body["requested"] == 4     # partial grant still settles
    [grant_b2] = core.on_message(
        Message(MsgType.REQUEST_TASKS, "b", {"n": 4}), 0.0)
    assert [tid for tid, _ in grant_b2.body["tasks"]] == [4, 5, 6, 7]


def test_backfill_respects_batches_when_tasks_are_pruned():
    """take_next() skipping disqualified tasks must not let a grant leak
    into the next batch."""
    cfg = ServerConfig(max_clients=4, assign_policy="backfill",
                       assign_batch=4)
    core = SchedulerCore(mk_tasks(12), cfg)
    # tasks are hardness-sorted (i,) for i=1..12; disqualify hardness >= 1
    # for tids 0-1 via min_hard would prune everything harder too, so
    # instead mark them non-grantable directly
    core.status[0] = core.status[1] = "pruned"
    core.client_joined("a", 0.0)
    [grant] = core.on_message(
        Message(MsgType.REQUEST_TASKS, "a", {"n": 4}), 0.0)
    # only tids 2,3 remain in the first batch; 4+ belongs to the next one
    assert [tid for tid, _ in grant.body["tasks"]] == [2, 3]
    [grant2] = core.on_message(
        Message(MsgType.REQUEST_TASKS, "a", {"n": 4}), 0.0)
    assert [tid for tid, _ in grant2.body["tasks"]] == [4, 5, 6, 7]


def test_backfill_policy_solves_everything_in_sim():
    def build(params):
        cfg = ServerConfig(max_clients=2, use_backup=False,
                           assign_policy="backfill", assign_batch=4)
        return SimCluster(mk_tasks(10, dur=0.5), cfg, params), 600
    rows = {}
    for mode in ("fixed", "events"):
        cl, until = build(SimParams(client_workers=2, mode=mode))
        srv = cl.run(until=until)
        rows[mode] = srv.final_results.rows
    assert rows["fixed"] == rows["events"]
    assert all(s == "done" for _, _, s in rows["events"])


# ---------------------------------------------------------------------------
# cost accounting end to end
# ---------------------------------------------------------------------------
def test_budget_capped_sim_scenario_ends_under_cap():
    cap = 400.0
    cfg = ServerConfig(max_clients=16, use_backup=False, workers_hint=4,
                       scale_policy="fixed", budget_cap=cap,
                       budget_reserve_s=90.0)
    cl = SimCluster(mk_tasks(24, dur=30.0), cfg,
                    SimParams(client_workers=4, seed=0, min_billing_s=60.0))
    srv = cl.run(until=3600)
    steps = 0
    while len(cl.engine.list_instances()) > 1 and steps < 3000:
        cl.step()
        steps += 1
    meter = CostMeter()
    meter.sync(cl.engine.billing_records())
    total = meter.accrued(cl.clock.now())
    assert total <= cap, (total, cap)
    # everything still solved, with a populated cost column
    assert all(r is not None for _, r, _ in srv.final_results.rows)
    assert srv.final_results.cost["total"] > 0
    assert any(c is not None for c in srv.final_results.row_costs)
    # the cap actually constrained the fleet (uncapped fixed creates more)
    created = sum(1 for _, k in cl.engine._kinds.items() if k == "client")
    assert created < 10, created


def test_demand_scaling_cheaper_than_fixed_under_min_billing():
    def run(scale):
        cfg = ServerConfig(max_clients=16, use_backup=False, workers_hint=4,
                           scale_policy=scale)
        cl = SimCluster(mk_tasks(24, dur=30.0), cfg,
                        SimParams(client_workers=4, seed=0,
                                  min_billing_s=60.0))
        srv = cl.run(until=3600)
        steps = 0
        while len(cl.engine.list_instances()) > 1 and steps < 3000:
            cl.step()
            steps += 1
        meter = CostMeter()
        meter.sync(cl.engine.billing_records())
        solved = sum(1 for _, r, _ in srv.final_results.rows
                     if r is not None)
        return meter.by_kind(cl.clock.now()).get("client", 0.0), solved
    fixed_cost, fixed_solved = run("fixed")
    demand_cost, demand_solved = run("demand")
    assert fixed_solved == demand_solved == 24
    assert demand_cost < 0.75 * fixed_cost, (demand_cost, fixed_cost)


def test_results_table_cost_column():
    tasks = mk_tasks(3)
    table = ResultsTable.build(
        tasks=tasks, original_index=[0, 1, 2],
        status=["done", "done", "pruned"], results={0: (1,), 1: (2,)},
        task_costs={0: 1.5, 1: 2.0}, cost={"total": 3.5})
    csv = table.to_csv()
    header, *rows = csv.splitlines()
    assert header.endswith(",status,cost")
    assert rows[0].endswith(",done,1.5")
    assert rows[2].endswith(",pruned,")      # unsolved: empty cost cell
    assert table.cost == {"total": 3.5}


def test_sim_results_carry_cost_columns():
    cl = SimCluster(mk_tasks(6, dur=0.5),
                    ServerConfig(max_clients=2, use_backup=False))
    srv = cl.run(until=600)
    table = srv.final_results
    assert table.cost is not None and table.cost["total"] > 0
    assert "client" in table.cost["by_kind"]
    solved_costs = [c for (p, r, s), c in zip(table.rows, table.row_costs,
                                              strict=True)
                    if s == "done"]
    assert solved_costs and all(c is not None and c > 0
                                for c in solved_costs)


def test_cost_meter_counts_min_billing_commitment():
    """An open instance with a minimum billing commitment is billed to
    the commitment, not just to now — budget projections must see spend
    that is locked in before it elapses."""
    m = CostMeter()
    m.sync([("c0", "client", 2.0, 10.0, None, 70.0)])   # min_end=70
    assert m.accrued(now=20.0) == pytest.approx(2.0 * 60.0)
    assert m.accrued(now=100.0) == pytest.approx(2.0 * 90.0)
    # closed records are billed by their (already floored) end time
    m.sync([("c0", "client", 2.0, 10.0, 70.0)])
    assert m.accrued(now=100.0) == pytest.approx(2.0 * 60.0)


def test_budget_denies_creation_when_commitments_exceed_cap():
    cfg = ServerConfig(max_clients=8, scale_policy="fixed",
                       budget_cap=300.0, budget_reserve_s=10.0)
    core = SchedulerCore(mk_tasks(8), cfg)
    # commitments already locked in (e.g. min-billing) blow the cap
    effs = core.on_tick(Tick(1.0, accrued_cost=600.0, burn_rate=1.0))
    assert not any(isinstance(e, CreateInstance) for e in effs)


def test_cost_meter_matches_engine_ground_truth():
    cl = SimCluster(mk_tasks(8, dur=0.5),
                    ServerConfig(max_clients=3, use_backup=False))
    cl.run(until=600)
    meter = CostMeter()
    meter.sync(cl.engine.billing_records())
    assert meter.accrued(cl.clock.now()) == pytest.approx(
        cl.engine.total_cost())


# ---------------------------------------------------------------------------
# satellites: kind registry at takeover, Hardness arity
# ---------------------------------------------------------------------------
def test_takeover_cleanup_uses_kind_registry_not_name_prefix():
    # workload long enough (~20s) that the kill at t=8 lands mid-run
    cl = SimCluster(mk_tasks(40, dur=2.0),
                    ServerConfig(max_clients=2, use_backup=True,
                                 health_update_limit=3.0))

    def ghosts_then_kill(c):
        now = c.clock.now()
        # a *client* that happens to be named like a backup must be reaped
        c.engine._instances["backup-impostor"] = now
        c.engine._kinds["backup-impostor"] = "client"
        # a *backup*-kind instance with an odd name must be left alone
        c.engine._instances["standby-7"] = now
        c.engine._kinds["standby-7"] = "backup"
        c.kill_primary()

    cl.at(8.0, ghosts_then_kill)
    srv = cl.run(until=900)
    assert srv.name == "primary*", "takeover must actually have happened"
    listed = cl.engine.list_instances()
    assert "backup-impostor" not in listed
    assert "standby-7" in listed
    assert sorted(p[0] for p, r, s in srv.final_results.rows
                  if r is not None) == list(range(1, 41))


def test_engine_instance_kind_survives_termination():
    cl = SimCluster(mk_tasks(4, dur=0.3),
                    ServerConfig(max_clients=2, use_backup=False))
    cl.run(until=600)
    for _ in range(300):
        cl.step()
    # clients BYE'd and were terminated, yet the registry still knows them
    assert cl.engine.list_instances() == ["primary"]
    assert any(k == "client" for k in cl.engine._kinds.values())
    for name, _, _, _ in cl.engine.cost_log:
        if name.startswith("client"):
            assert cl.engine.instance_kind(name) == "client"


def test_hardness_geq_raises_on_arity_mismatch():
    with pytest.raises(ValueError, match="arities"):
        Hardness((1, 2)).geq(Hardness((1,)))
    with pytest.raises(ValueError, match="arities"):
        Hardness((1,)).geq(Hardness((1, 2)))
