"""Bad fixture: ShardCoordinator that forgets gossip state on resume
and reads the wall clock inside the pure meta-scheduling core."""
import time


class ShardCoordinator:
    def __init__(self, n_shards):
        self.n_shards = n_shards
        self.seen = set()
        self.pending = [[] for _ in range(n_shards)]
        self.last_pump_at = time.time()   # purity: wall-clock read

    def observe(self, shard_id, frontier_values):
        fresh = []
        for hv in frontier_values:
            hv = tuple(hv)
            if hv in self.seen:
                continue
            self.seen.add(hv)
            fresh.append(hv)
            for j in range(self.n_shards):
                if j != shard_id:
                    self.pending[j].append(hv)
        return fresh

    def snapshot(self):
        # BUG: "pending" and "last_pump_at" are missing — queued gossip
        # deliveries are silently dropped on resume, so a shard that was
        # owed a pruning frontier never receives it
        return {
            "n_shards": self.n_shards,
            "seen": sorted(self.seen),
        }

    @classmethod
    def restore(cls, snap):
        coord = cls.__new__(cls)
        coord.n_shards = snap["n_shards"]
        coord.seen = {tuple(hv) for hv in snap["seen"]}
        return coord
