"""Fixture: direct compiler-params access + an unchecked // grid."""
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.experimental.pallas.tpu import TPUCompilerParams  # noqa: F401


def bad_kernel(x, block=128):
    S = x.shape[0]
    params = pltpu.CompilerParams(dimension_semantics=("arbitrary",))
    grid = (S // block,)
    return pl.pallas_call(lambda x_ref, o_ref: None, grid=grid,
                          compiler_params=params)(x)
