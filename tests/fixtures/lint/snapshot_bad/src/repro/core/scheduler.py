"""Fixture: a field snapshot() forgets, a key __init__ never assigns."""


class SchedulerCore:
    def __init__(self, config):
        self.config = config
        self.tasks = []
        self._budget_hit = False

    def snapshot(self):
        return {"config": self.config, "stale_key": 0}

    @classmethod
    def restore(cls, snap):
        core = cls(snap["config"])
        core.tasks = []
        return core
