"""Fixture: a real violation silenced by a line suppression."""
import time


class SchedulerCore:
    def on_tick(self):
        return time.time()  # expolint: disable=core-purity
