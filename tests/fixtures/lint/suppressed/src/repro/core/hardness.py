# expolint: disable-file=core-purity
"""Fixture: a whole file opted out via file-level suppression."""
import time


def measure():
    return time.time()
