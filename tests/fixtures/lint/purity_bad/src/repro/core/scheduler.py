"""Fixture: every purity ban at once — clock, env, RNG, I/O, threads."""
import os
import random
import threading
import time


class SchedulerCore:
    def on_tick(self):
        now = time.time()
        tag = os.environ["EXPO_TAG"]
        jitter = random.random()
        with open("/tmp/expo.log", "w") as fh:
            fh.write(str((now, tag, jitter, threading.active_count())))
