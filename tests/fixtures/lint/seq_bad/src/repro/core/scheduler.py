"""Fixture: srv_seq broadcasts — the exact divergence class of PR 4."""
from repro.core.messages import MsgType


class SchedulerCore:
    def __init__(self):
        self.clients = {}
        self.srv_seq = 0
        self.ctrl_seq = 0

    def _send(self, ci, mtype, body=None):
        pass

    def pause_all(self):
        for ci in self.clients.values():
            self._send(ci, MsgType.STOP)

    def fan_out(self):
        return [Send(client=name, srv_seq=self.srv_seq)
                for name in self.clients]

    def mixed_planes(self, ci):
        return Send(client=ci.name, srv_seq=1, ctrl_seq=2)
