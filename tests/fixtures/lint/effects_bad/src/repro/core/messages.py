import enum


class MsgType(enum.Enum):
    PING = 1
    PONG = 2
