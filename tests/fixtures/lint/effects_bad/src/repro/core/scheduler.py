"""Fixture: an unhandled event, an undispatched effect, a void message."""
from dataclasses import dataclass

from repro.core.messages import MsgType


# ---- typed events (inputs) ----
@dataclass
class Tick:
    now: float


@dataclass
class ClientLost:
    name: str


# ---- typed effects (outputs) ----
@dataclass
class Send:
    client: str


@dataclass
class LaunchProbe:
    target: str


class SchedulerCore:
    def handle(self, event):
        if isinstance(event, Tick):
            return [Send(client="a"), LaunchProbe(target="b")]
        return []

    def ping(self, ci):
        self._send(ci, MsgType.PING)
