from repro.core.messages import MsgType


class Client:
    def act(self, msg):
        if msg.type == MsgType.PONG:
            return "pong"
        return None
