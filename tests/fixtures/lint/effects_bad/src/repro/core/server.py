from repro.core.scheduler import Send


class Server:
    def _apply(self, eff, now):
        if isinstance(eff, Send):
            pass
