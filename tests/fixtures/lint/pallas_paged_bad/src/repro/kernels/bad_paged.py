"""Fixture: unchecked // feeding a grid through PrefetchScalarGridSpec.

No direct ``pallas_call`` in the offending function — the grid reaches
the kernel via the grid-spec object, which the pallas-rules divisibility
check must still catch.
"""
from jax.experimental.pallas import tpu as pltpu


def bad_paged_grid(k_pool, page_size=16):
    Smax = k_pool.shape[0] * k_pool.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Smax // page_size,),
        in_specs=[],
        out_specs=None,
    )
    return grid_spec
