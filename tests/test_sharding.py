"""Sharding rules: logical->physical resolution, divisibility fallbacks,
ZeRO-1 state specs; multi-device parity via subprocess (host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P


def test_rules_resolution_and_divisibility(monkeypatch):
    # build rules without touching global device state: fake mesh-like
    import jax

    mesh = jax.make_mesh((1,), ("model",))  # 1 real CPU device
    from repro.sharding.rules import make_rules

    rules = make_rules(mesh)
    # model axis size 1 divides everything
    assert rules.spec(("embed", "ffn"), (8, 16)) == P(None, "model")
    # unknown logical name -> replicated
    assert rules.spec(("nope",), (8,)) == P(None)


def test_zero1_spec_adds_dp_axis():
    from repro.sharding.rules import ShardingRules
    from repro.sharding.zero import zero1_spec

    class FakeMesh:          # avoids touching jax device state; data axis = 4
        axis_names = ("data",)
        shape = {"data": 4}

    rules = ShardingRules(mesh=FakeMesh(), table={})
    s = zero1_spec(P(None, "model"), (8, 16), rules)
    assert s == P("data", "model")
    # indivisible dim (7 % 4 != 0) -> unchanged
    s2 = zero1_spec(P(), (7,), rules)
    assert s2 == P()
    # first dim taken by 'model', second divisible -> data lands on dim 1
    s3 = zero1_spec(P("model"), (16, 8), rules)
    assert s3 == P("model", "data")


SUBPROCESS_PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import reduced_config
    from repro.models import lm
    from repro.models.params import init_params, param_shardings
    from repro.sharding.rules import make_rules, use_rules
    from repro.sharding.zero import opt_state_shardings
    from repro.train.optimizer import get_optimizer
    from repro.train.schedule import constant
    from repro.train.train_step import make_train_step

    cfg = reduced_config("@ARCH@")
    descr = lm.make_lm(cfg)
    params = init_params(descr, jax.random.PRNGKey(0))
    opt = get_optimizer("adamw")
    state = opt.init(params)
    B, S = 4, 64
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    step_fn = make_train_step(cfg, opt, constant(1e-3))

    # single-device result
    p1, s1, m1 = jax.jit(step_fn)(params, state, batch, jnp.int32(0))
    loss1 = float(m1["loss"])

    # sharded result on a 2x4 mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = make_rules(mesh)
    psh = param_shardings(descr, rules)
    osh = opt_state_shardings("adamw", descr, rules, zero1=True)
    params_s = jax.tree_util.tree_map(jax.device_put, params, psh)
    state_s = jax.tree_util.tree_map(jax.device_put, state, osh)
    def wrapped(p, s, b, t):
        from repro.sharding.rules import use_rules as ur
        with ur(rules):
            return step_fn(p, s, b, t)
    with mesh:
        p2, s2, m2 = jax.jit(wrapped, in_shardings=(psh, osh, None, None),
                             out_shardings=(psh, osh, None))(
            params_s, state_s, batch, jnp.int32(0))
    loss2 = float(m2["loss"])
    assert abs(loss1 - loss2) < 5e-2, (loss1, loss2)
    # parameters after one step agree across the mesh boundary
    f1 = jax.tree_util.tree_leaves(p1)[0].astype(jnp.float32)
    f2 = jax.tree_util.tree_leaves(p2)[0].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               atol=3e-2, rtol=3e-2)
    print("PARITY_OK", loss1, loss2)
""")


@pytest.mark.parametrize("arch", ["smollm-360m", "olmoe-1b-7b"])
def test_sharded_train_step_parity_subprocess(arch):
    """One optimizer step on 1 device == on a 2x4 DPxTP mesh (8 host
    devices in a subprocess so this process keeps 1 device)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_PARITY.replace("@ARCH@", arch)],
        capture_output=True, text=True, env=env, timeout=480,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "PARITY_OK" in r.stdout
