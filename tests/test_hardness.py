"""Hardness lattice + min_hard antichain: unit + hypothesis property tests."""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.hardness import Hardness, MinHardSet

tuples = st.tuples(st.integers(0, 6), st.integers(0, 6), st.integers(0, 6))


def test_geq_basic():
    assert Hardness((2, 3)).geq(Hardness((1, 3)))
    assert not Hardness((2, 3)).geq(Hardness((3, 1)))
    assert Hardness((2, 3)).geq(Hardness((2, 3)))  # reflexive ("as hard")


def test_minhard_keeps_minimal_elements():
    m = MinHardSet()
    assert m.add(Hardness((5, 5)))
    assert m.add(Hardness((1, 9)))      # incomparable: retained
    assert not m.add(Hardness((6, 6)))  # dominates (5,5): rejected
    assert m.add(Hardness((4, 4)))      # dominates nothing; evicts (5,5)
    vals = set(m.snapshot())
    assert (5, 5) not in vals and (4, 4) in vals and (1, 9) in vals


@given(st.lists(tuples, min_size=1, max_size=30))
@settings(max_examples=200, deadline=None)
def test_minhard_antichain_invariant(hs):
    m = MinHardSet()
    for h in hs:
        m.add(Hardness(h))
    items = list(m)
    # (1) pairwise incomparable (antichain)
    for i, a in enumerate(items):
        for b in items[i + 1:]:
            assert not (a.geq(b) or b.geq(a)), (a, b)
    # (2) every inserted hardness is disqualified afterwards
    for h in hs:
        assert m.disqualifies(Hardness(h))


@given(st.lists(tuples, min_size=1, max_size=20), tuples)
@settings(max_examples=200, deadline=None)
def test_disqualifies_is_upward_closed(hs, probe):
    """If h is disqualified, anything dominating h is too (monotonicity)."""
    m = MinHardSet()
    for h in hs:
        m.add(Hardness(h))
    h = Hardness(probe)
    if m.disqualifies(h):
        bigger = Hardness(tuple(x + 1 for x in probe))
        assert m.disqualifies(bigger)


@given(st.lists(tuples, min_size=1, max_size=20))
@settings(max_examples=100, deadline=None)
def test_snapshot_restore_roundtrip(hs):
    m = MinHardSet()
    for h in hs:
        m.add(Hardness(h))
    m2 = MinHardSet()
    m2.restore(m.snapshot())
    assert set(m.snapshot()) == set(m2.snapshot())
    for h in hs:
        assert m2.disqualifies(Hardness(h))
