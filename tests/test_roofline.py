"""Roofline extraction: HLO collective parsing, term math, extrapolation."""
import pytest

from repro.launch.roofline import (Roofline, analyze, parse_collectives,
                                   PEAK_FLOPS, HBM_BW, ICI_BW)

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[4,1024]{1,0} parameter(0)
  %ag = bf16[8,1024]{1,0} all-gather(%p0), replica_groups={}
  %ar = f32[256]{0} all-reduce(%x), to_apply=%add
  %rs = f32[2,64]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[16,32]{1,0} all-to-all(%z), dimensions={0}
  %cp = u8[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %ag2s = (bf16[4,8]{1,0}, bf16[8,8]{1,0}) all-gather-start(%q)
  %ag2d = bf16[8,8]{1,0} all-gather-done(%ag2s)
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(HLO)
    assert st.count_by_kind["all-gather"] >= 1
    assert st.count_by_kind["all-reduce"] == 1
    # all-gather result: 8*1024*2 bytes
    assert st.bytes_by_kind["all-gather"] >= 8 * 1024 * 2
    # all-reduce: 2x factor on 256*4 bytes
    assert st.bytes_by_kind["all-reduce"] == 2 * 256 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 2 * 64 * 4
    assert st.bytes_by_kind["all-to-all"] == 16 * 32 * 2
    assert st.bytes_by_kind["collective-permute"] == 128


def test_analyze_terms_and_dominant():
    r = analyze(arch="x", shape="train_4k", mesh_desc="data16xmodel16",
                chips=256,
                cost={"flops": 1e12, "bytes accessed": 1e9},
                hlo_text=HLO, model_flops=200e12)
    assert r.compute_s == pytest.approx(1e12 * 256 / (256 * PEAK_FLOPS))
    assert r.memory_s == pytest.approx(1e9 * 256 / (256 * HBM_BW))
    assert r.collective_s == pytest.approx(
        r.collective_bytes_per_chip / ICI_BW)
    assert r.dominant == "compute"
    assert 0 < r.useful_ratio <= 1.0
    assert 0 < r.roofline_fraction <= 1.0


def test_probe_extrapolation_linear():
    """m(L) = a + b*L measured at two L values extrapolates exactly."""
    from repro.launch.aggregate import extrapolate_linear

    base = {"hlo_flops": 10.0, "hlo_bytes": 4.0,
            "collective_bytes_per_chip": 2.0}
    bumped = [{"hlo_flops": 16.0, "hlo_bytes": 5.0,
               "collective_bytes_per_chip": 3.5}]
    full = extrapolate_linear(base, bumped, base_counts=(2,),
                              full_counts=(32,))
    assert full["hlo_flops"] == pytest.approx(10 + 6 * 30)
    assert full["hlo_bytes"] == pytest.approx(4 + 1 * 30)
    assert full["collective_bytes_per_chip"] == pytest.approx(2 + 1.5 * 30)


def test_probe_extrapolation_two_segments():
    from repro.launch.aggregate import extrapolate_linear

    base = {"hlo_flops": 10.0}
    bumped = [{"hlo_flops": 13.0}, {"hlo_flops": 15.0}]  # +seg0, +seg1
    full = extrapolate_linear(base, bumped, base_counts=(1, 2),
                              full_counts=(3, 58))
    assert full["hlo_flops"] == pytest.approx(10 + 3 * 2 + 5 * 56)
