"""Late-added behaviours: poison-task retry cap (beyond-paper) and the
kv_shard_model decode variant."""
import json
import os
import subprocess
import sys

import pytest

from repro.core.server import ServerConfig
from repro.core.sim import SimCluster, SimParams, SimTask

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class AlwaysCrash(SimTask):
    def run(self):
        raise RuntimeError("poison")


def test_poison_task_is_capped_not_retried_forever():
    tasks = [SimTask((1, 0), ("n", "id"), (1,), 0.3, None, (1,)),
             AlwaysCrash((2, 0), ("n", "id"), (2,), 0.3, None, (2,)),
             SimTask((3, 0), ("n", "id"), (3,), 0.3, None, (3,))]
    cl = SimCluster(tasks, ServerConfig(max_clients=1, use_backup=False,
                                        max_task_attempts=3),
                    SimParams(client_workers=1))
    srv = cl.run(until=600)   # finishes => no livelock
    status = {p[0]: s for p, r, s in srv.final_results.rows}
    assert status[1] == "done" and status[3] == "done"
    assert status[2] == "pruned"          # capped after 3 attempts
    assert srv.attempts.get(
        [i for i, t in enumerate(srv.tasks)
         if t.parameters()[0] == 2][0]) == 4


def test_kv_shard_model_reduces_decode_bytes():
    """Sharding the cache sequence over the TP axis must shrink the
    decode-cell bytes/device (8 host devices, 2x4 mesh)."""
    env = dict(os.environ, PYTHONPATH="src", REPRO_DRYRUN_DEVICES="8")

    def run(variant, out):
        # granite: MQA (kv=1) -> the cache can never shard over kv_heads,
        # so seq-over-model is the only lever (as on the production mesh)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", "granite-20b", "--shape", "decode_32k",
               "--mesh-shape", "2", "4", "--mesh-axes", "data", "model",
               "--json", out] + (["--variant"] + variant if variant else [])
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=520, cwd=ROOT)
        assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
        with open(out) as f:
            return json.load(f)

    base = run([], "/tmp/kvshard_base.json")
    shard = run(["kv_shard_model=1"], "/tmp/kvshard_on.json")
    b0 = base["bytes_per_device_inputs"]
    b1 = shard["bytes_per_device_inputs"]
    # cache dominates granite decode; 4-way extra seq sharding > 2x total
    assert b1 < b0 / 2, (b0, b1)
