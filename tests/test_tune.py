"""Tests for the ``repro.tune`` autotuning subsystem: the persistent
best-config cache, its wiring into ``kernels/ops.py`` dispatch, the
measurement utilities, and the sim-engine dogfood sweep (the sweep runs
through ``Experiment`` with the paper's timeout/domino pruning live).
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.tune import cache as tc
from repro.tune import space as tspace
from repro.tune.measure import robust_mean_us

SHAPE = {"b": 1, "s": 256, "h": 4, "kvh": 2, "d": 64}


@pytest.fixture
def cache_file(tmp_path, monkeypatch):
    """Fresh cache file + env override; singleton reset around the test."""
    path = str(tmp_path / "tune_cache.json")
    monkeypatch.setenv(tc.ENV_VAR, path)
    tc.reset()
    yield path
    tc.reset()


def _flash_qkv(s=256, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, s, 4, 64), dtype)
    k = jax.random.normal(ks[1], (1, s, 2, 64), dtype)
    v = jax.random.normal(ks[2], (1, s, 2, 64), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# cache semantics
# ---------------------------------------------------------------------------
def test_cache_round_trip(cache_file):
    cache = tc.TuneCache(cache_file)
    key = cache.store("flash_attention", SHAPE, "float32", "interpret",
                      {"block_q": 256, "block_k": 64}, runtime_us=10.0,
                      default_us=20.0)
    assert key in cache.entries()
    # a second instance reads the same file from scratch
    got = tc.TuneCache(cache_file).lookup(
        "flash_attention", SHAPE, "float32", "interpret")
    assert got == {"block_q": 256, "block_k": 64}
    # other backend / dtype / kernel are misses
    assert tc.TuneCache(cache_file).lookup(
        "flash_attention", SHAPE, "float32", "tpu") is None
    assert tc.TuneCache(cache_file).lookup(
        "flash_attention", SHAPE, "bfloat16", "interpret") is None
    assert tc.TuneCache(cache_file).lookup(
        "decode_attention", SHAPE, "float32", "interpret") is None


def test_cache_atomic_write_crash_safety(cache_file, monkeypatch):
    cache = tc.TuneCache(cache_file)
    cache.store("flash_attention", SHAPE, "float32", "interpret",
                {"block_q": 256, "block_k": 64}, runtime_us=10.0)
    before = json.load(open(cache_file, encoding="utf-8"))

    def boom(*a, **kw):
        raise OSError("disk full mid-serialise")

    monkeypatch.setattr(json, "dump", boom)
    with pytest.raises(OSError):
        cache.store("ssd_scan", {"b": 1, "s": 128}, "float32", "interpret",
                    {"chunk": 32}, runtime_us=5.0)
    monkeypatch.undo()
    # the crash never touched the good file, and left no temp droppings
    assert json.load(open(cache_file, encoding="utf-8")) == before
    leftovers = [f for f in os.listdir(os.path.dirname(cache_file))
                 if f.endswith(".tmp")]
    assert leftovers == []


def test_cache_stale_hash_invalidation(cache_file):
    cache = tc.TuneCache(cache_file)
    cache.store("flash_attention", SHAPE, "float32", "interpret",
                {"block_q": 256, "block_k": 64}, runtime_us=10.0)
    # simulate the kernel module having been edited since tuning
    payload = json.load(open(cache_file, encoding="utf-8"))
    for e in payload["entries"].values():
        e["src_hash"] = "deadbeef0000"
    with open(cache_file, "w", encoding="utf-8") as fh:
        json.dump(payload, fh)
    assert tc.TuneCache(cache_file).lookup(
        "flash_attention", SHAPE, "float32", "interpret") is None


def test_cache_shape_bucket_fallback(cache_file):
    cache = tc.TuneCache(cache_file)
    cache.store("flash_attention", SHAPE, "float32", "interpret",
                {"block_q": 256, "block_k": 64}, runtime_us=10.0)
    # nearby shape, same field set -> nearest-bucket fallback hit
    near = dict(SHAPE, s=512)
    assert cache.lookup("flash_attention", near, "float32",
                        "interpret") == {"block_q": 256, "block_k": 64}
    # different field set -> no fallback across workload identities
    other = {"b": 1, "sk": 256, "h": 4, "kvh": 2, "d": 64}
    assert cache.lookup("flash_attention", other, "float32",
                        "interpret") is None


def test_cache_corrupt_file_treated_as_empty(cache_file):
    with open(cache_file, "w", encoding="utf-8") as fh:
        fh.write("{not json")
    cache = tc.TuneCache(cache_file)
    assert cache.lookup("flash_attention", SHAPE, "float32",
                        "interpret") is None
    # and storing over the corpse works
    cache.store("flash_attention", SHAPE, "float32", "interpret",
                {"block_q": 64, "block_k": 64}, runtime_us=1.0)
    assert cache.lookup("flash_attention", SHAPE, "float32",
                        "interpret") == {"block_q": 64, "block_k": 64}


def test_cache_disabled_via_env(monkeypatch):
    monkeypatch.setenv(tc.ENV_VAR, "")
    tc.reset()
    try:
        assert tc.best_config("flash_attention", SHAPE, "float32") is None
        with pytest.raises(RuntimeError):
            tc.get_cache().store("flash_attention", SHAPE, "float32",
                                 "interpret", {}, runtime_us=1.0)
    finally:
        tc.reset()


def test_shape_bucket_rounds_up_pow2():
    assert tc.shape_bucket({"s": 300, "b": 1, "h": 3}) == "b1-h4-s512"
    assert tc.shape_bucket({"s": 256}) == "s256"


# ---------------------------------------------------------------------------
# ops dispatch wiring (explicit arg > cache hit > default)
# ---------------------------------------------------------------------------
@pytest.fixture
def flash_spy(monkeypatch):
    """Record the kwargs ops dispatch hands the flash kernel (the kernel
    itself is stubbed out — these tests probe the wiring, not the math)."""
    import repro.kernels.flash_attention as fk

    seen = {}

    def spy(q, k, v, **kw):
        seen.clear()
        seen.update(kw)
        return jnp.zeros_like(q)

    monkeypatch.setattr(fk, "flash_attention", spy)
    return seen


def test_ops_flash_miss_uses_defaults(cache_file, monkeypatch, flash_spy):
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    from repro.kernels import ops

    ops.flash_attention(*_flash_qkv())
    assert flash_spy["block_q"] == 128 and flash_spy["block_k"] == 128


def test_ops_flash_hit_uses_tuned_blocks(cache_file, monkeypatch,
                                         flash_spy):
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    tc.get_cache().store("flash_attention", SHAPE, "float32", "interpret",
                         {"block_q": 256, "block_k": 64}, runtime_us=10.0)
    from repro.kernels import ops

    ops.flash_attention(*_flash_qkv())
    assert flash_spy["block_q"] == 256 and flash_spy["block_k"] == 64
    # explicit argument always beats the cache
    ops.flash_attention(*_flash_qkv(), block_q=32)
    assert flash_spy["block_q"] == 32 and flash_spy["block_k"] == 64


def test_ops_invalid_cached_config_falls_back(cache_file, monkeypatch,
                                              flash_spy):
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    # 100 does not divide s=256 -> dispatch degrades to the default
    tc.get_cache().store("flash_attention", SHAPE, "float32", "interpret",
                         {"block_q": 100, "block_k": 64}, runtime_us=10.0)
    from repro.kernels import ops

    ops.flash_attention(*_flash_qkv())
    assert flash_spy["block_q"] == 128 and flash_spy["block_k"] == 64


def test_ops_ssd_chunk_none_matches_default(monkeypatch):
    """No cache: ``chunk=None`` is byte-identical to the built-in 64."""
    monkeypatch.setenv(tc.ENV_VAR, "")
    monkeypatch.setenv("REPRO_PALLAS", "ref")
    tc.reset()
    try:
        from repro.kernels import ops

        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        x = jax.random.normal(ks[0], (1, 128, 2, 16))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 2)))
        A = -jnp.exp(jax.random.normal(ks[2], (2,)) * 0.3)
        Bm = jax.random.normal(ks[3], (1, 128, 1, 16))
        Cm = jax.random.normal(ks[4], (1, 128, 1, 16))
        auto = ops.ssd_scan(x, dt, A, Bm, Cm)
        manual = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=64)
        assert np.array_equal(np.asarray(auto), np.asarray(manual))
    finally:
        tc.reset()


def test_engine_resolve_page_size(cache_file):
    from types import SimpleNamespace

    from repro.serve.engine import _DEFAULT_PAGE_SIZE, _resolve_page_size

    cfg = SimpleNamespace(num_heads=4, num_kv_heads=2, head_dim=64)
    # miss -> default
    assert _resolve_page_size(cfg, 4, 256) == _DEFAULT_PAGE_SIZE
    shape = {"b": 4, "sk": 256, "kvh": 2, "g": 2, "d": 64}
    tc.get_cache().store("decode_attention_paged", shape, "float32",
                         tc.dispatch_backend(), {"page_size": 32},
                         runtime_us=10.0)
    assert _resolve_page_size(cfg, 4, 256) == 32
    # a stale/out-of-range tuned value degrades to the default
    tc.get_cache().store("decode_attention_paged", shape, "float32",
                         tc.dispatch_backend(), {"page_size": 4096},
                         runtime_us=10.0)
    assert _resolve_page_size(cfg, 4, 256) == _DEFAULT_PAGE_SIZE
    # cfgs without GQA attention fields never consult the cache
    assert _resolve_page_size(SimpleNamespace(), 4, 256) == \
        _DEFAULT_PAGE_SIZE


# ---------------------------------------------------------------------------
# search space + measurement utilities
# ---------------------------------------------------------------------------
def test_space_grid_static_validity():
    for kernel, spec in tspace.SPECS.items():
        sp = tspace.build_space(kernel, dict(spec.smoke_shape),
                                adversarial=4, seed=0)
        cells = list(sp.cells())
        assert cells, kernel
        for cell in cells:
            assert tspace.valid(kernel, cell), (kernel, cell)
        # the dispatch default is always in the grid (the incumbent)
        assert any(all(c[k] == v for k, v in spec.defaults.items())
                   for c in cells), kernel


def test_runner_rejects_invalid_config_statically():
    from repro.tune import runner

    cell = dict(SHAPE, dtype="float32", block_q=100, block_k=64)
    with pytest.raises(ValueError, match="divisibility"):
        runner.measure_cell("flash_attention", cell)


def test_robust_mean_rejects_outliers():
    mean, kept = robust_mean_us([10.0, 11.0, 12.0, 500.0],
                                outlier_frac=0.25)
    assert kept == 3
    assert mean == pytest.approx(11.0)
    with pytest.raises(ValueError):
        robust_mean_us([])


def test_predicted_cost_orders_pathological_last():
    spec = tspace.SPECS["flash_attention"]
    shape = dict(spec.smoke_shape)
    sane = {**shape, "dtype": "float32", "block_q": 128, "block_k": 128}
    bad = {**shape, "dtype": "float32", "block_q": 8, "block_k": 8}
    assert tspace.predicted_cost_us("flash_attention", bad) > \
        tspace.predicted_cost_us("flash_attention", sane)
    assert tspace.hardness_of("flash_attention", bad) > \
        tspace.hardness_of("flash_attention", sane)


# ---------------------------------------------------------------------------
# the dogfood sweep: Experiment-driven tuning, domino pruning live
# ---------------------------------------------------------------------------
def test_sim_sweep_dogfood(cache_file, monkeypatch):
    """End-to-end: sim-engine sweep on an adversarial grid prunes via the
    paper's timeout/domino rule, stays under its budget cap, persists the
    winner, and ops dispatch picks the tuned value up afterwards."""
    monkeypatch.delenv("REPRO_PALLAS", raising=False)   # XLA ref: fast
    from repro.tune.tuner import tune

    # the smoke grid: deterministic on the sim engine, and sized so the
    # pathological configs outlast the sane queue (>= one task is still
    # pending when the first timeout fires -> a provable domino prune)
    shape = dict(tspace.SPECS["ssd_scan"].smoke_shape)
    rep = tune("ssd_scan", shape=shape, engine="sim", adversarial=4,
               seed=0, budget_cap=150.0, cache_path=cache_file)
    assert rep.explored == len(rep.configs) > 0
    assert rep.pruned >= 1, rep.summary()         # domino rule fired
    assert rep.timed_out >= 1, rep.summary()
    assert rep.measured >= 1, rep.summary()
    assert rep.speedup >= 1.0 - 1e-9              # incumbent is the floor
    assert rep.under_cap is True
    assert rep.cost_total is not None and rep.cost_total <= 150.0
    # per-config CostMeter attribution present on the records
    assert any(c.get("cost") is not None for c in rep.configs)
    # pruned configs never ran: no runtime on their records
    from repro.core.scheduler import DONE

    assert all("runtime_us" not in c for c in rep.configs
               if c["status"] != DONE)
    # winner persisted under the dispatch backend
    entry = tc.TuneCache(cache_file).lookup(
        "ssd_scan", shape, "float32", tc.dispatch_backend())
    assert entry == rep.best_config

    # ...and dispatch actually consumes it (chunk=None -> tuned chunk)
    from repro.kernels import ops, ref

    seen = {}
    orig = ref.ssd_chunked_ref

    def spy(*a, **kw):
        seen.update(kw)
        return orig(*a, **kw)

    monkeypatch.setattr(ref, "ssd_chunked_ref", spy)
    tc.reset()
    try:
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        x = jax.random.normal(ks[0], (1, 128, 2, 16))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 2)))
        A = -jnp.exp(jax.random.normal(ks[2], (2,)) * 0.3)
        Bm = jax.random.normal(ks[3], (1, 128, 1, 16))
        Cm = jax.random.normal(ks[4], (1, 128, 1, 16))
        ops.ssd_scan(x, dt, A, Bm, Cm)
        assert seen["chunk"] == rep.best_config["chunk"]
    finally:
        tc.reset()


def test_env_cache_pickup(monkeypatch):
    """CI tune-job handoff: a cache produced by ``python -m repro.tune``
    in a *previous process* steers ops dispatch in this one.  Skips when
    no populated ``REPRO_TUNE_CACHE`` with an interpret-backend flash
    entry is present (the CI tune job provides one)."""
    path = os.environ.get(tc.ENV_VAR)
    if not path or not os.path.exists(path):
        pytest.skip(f"no populated {tc.ENV_VAR} cache provided")
    entries = [e for e in tc.TuneCache(path).entries().values()
               if e["kernel"] == "flash_attention"
               and e["backend"] == "interpret"]
    if not entries:
        pytest.skip("cache has no interpret flash_attention entry")
    entry = entries[0]

    import repro.kernels.flash_attention as fk

    seen = {}
    monkeypatch.setattr(
        fk, "flash_attention",
        lambda q, k, v, **kw: (seen.update(kw), jnp.zeros_like(q))[1])
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    tc.reset()
    try:
        from repro.kernels import ops

        s = entry["shape"]
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (s["b"], s["s"], s["h"], s["d"]))
        k = jax.random.normal(ks[1], (s["b"], s["s"], s["kvh"], s["d"]))
        v = jax.random.normal(ks[2], (s["b"], s["s"], s["kvh"], s["d"]))
        ops.flash_attention(q, k, v)
        assert seen["block_q"] == entry["config"]["block_q"]
        assert seen["block_k"] == entry["config"]["block_k"]
    finally:
        tc.reset()
