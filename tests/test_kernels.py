"""Pallas kernel validation: sweep shapes/dtypes, assert_allclose vs the
pure-jnp oracles in repro.kernels.ref (kernels run in interpret=True on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import (attention_ref, ssd_chunked_ref,
                               ssd_decode_step_ref, ssd_sequential_ref)
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,H,K,D", [
    (128, 4, 4, 64),    # MHA
    (256, 4, 2, 64),    # GQA
    (256, 8, 1, 128),   # MQA
    (128, 4, 2, 96),    # phi-3 head dim
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, K, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    B = 2
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, K, D), dtype)
    v = jax.random.normal(ks[2], (B, S, K, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), **_tol(dtype))


def test_flash_attention_blocks_and_mla_dims():
    """Uneven Dk != Dv (MLA prefill) + asymmetric blocks."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H = 1, 256, 4
    q = jax.random.normal(ks[0], (B, S, H, 192))
    k = jax.random.normal(ks[1], (B, S, H, 192))
    v = jax.random.normal(ks[2], (B, S, H, 128))
    out = flash_attention(q, k, v, causal=True, scale=192 ** -0.5,
                          block_q=128, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, scale=192 ** -0.5)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_q_offset():
    """Chunked-prefill style: queries start at a KV offset."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, Sq, Sk, H, D = 1, 64, 192, 2, 64
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, H, D))
    v = jax.random.normal(ks[2], (B, Sk, H, D))
    out = flash_attention(q, k, v, causal=True, q_offset=128,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, q_offset=128)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,H,P,G,N,chunk", [
    (128, 4, 32, 2, 16, 32),
    (128, 2, 64, 1, 64, 64),
    (64, 6, 32, 1, 128, 16),   # mamba2-130m-like group/state
    (96, 4, 32, 2, 16, 32),    # chunk does not divide -> clamps to min
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(S, H, P, G, N, chunk, dtype):
    if S % chunk != 0:
        pytest.skip("S must be divisible by chunk")
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B = 2
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, G, N), dtype)
    y_seq, hT = ssd_sequential_ref(x, dt, A, Bm, Cm)
    y_k, hT_k = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         return_final_state=True, interpret=True)
    tol = dict(atol=1e-1, rtol=1e-1) if dtype == jnp.bfloat16 \
        else dict(atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(
        y_k.astype(jnp.float32), y_seq.astype(jnp.float32), **tol)
    np.testing.assert_allclose(hT_k, hT, **tol)


def test_ssd_chunked_ref_matches_sequential():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, G, N = 2, 256, 4, 32, 2, 32
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    for chunk in (32, 64, 128):
        y = ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=chunk)
        y_seq, _ = ssd_sequential_ref(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(y, y_seq, atol=2e-3, rtol=2e-3)


def test_ssd_state_carry_equals_one_shot():
    """Splitting a sequence into two kernel calls with h0 carry == one shot."""
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, S, H, P, G, N = 1, 128, 2, 32, 1, 16
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y_full, h_full = ssd_scan(x, dt, A, Bm, Cm, chunk=32,
                              return_final_state=True, interpret=True)
    half = S // 2
    y1, h1 = ssd_scan(x[:, :half], dt[:, :half], A, Bm[:, :half],
                      Cm[:, :half], chunk=32, return_final_state=True,
                      interpret=True)
    y2, h2 = ssd_scan(x[:, half:], dt[:, half:], A, Bm[:, half:],
                      Cm[:, half:], chunk=32, h0=h1,
                      return_final_state=True, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h2, h_full, atol=2e-4, rtol=2e-4)


def test_ssd_decode_step_matches_sequential_tail():
    """Prefill state + N decode steps == full sequential scan."""
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    B, S, H, P, G, N = 1, 64, 2, 16, 1, 16
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y_full, h_full = ssd_sequential_ref(x, dt, A, Bm, Cm)
    cut = S - 4
    _, h = ssd_sequential_ref(x[:, :cut], dt[:, :cut], A, Bm[:, :cut],
                              Cm[:, :cut])
    ys = []
    for t in range(cut, S):
        y, h = ssd_decode_step_ref(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_full[:, cut:],
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h, h_full, atol=2e-4, rtol=2e-4)
